"""Fault-tolerant training driver.

The loop a production job runs: deterministic data, async checkpoints,
preemption-safe shutdown, straggler monitoring, failure recovery
(checkpoint-restart on simulated chip loss), and elastic restart onto a
different mesh (checkpoint resharding).

run() returns a log of per-step metrics; recover-and-continue is exercised
by tests/test_runtime.py (inject failure at step k, restart, verify the
loss trajectory matches an uninterrupted run exactly — possible because
both data and init are deterministic functions of (seed, step)).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.checkpoint import CheckpointManager
from repro.configs.base import ArchConfig, ParallelConfig
from repro.core import telemetry
from repro.data import DataConfig, make_loader
from repro.optim import adamw
from repro.parallel import stages
from repro.runtime.health import (
    FailureInjector, Heartbeat, RankFailure, SimulatedDeviceFailure,
    StragglerWatchdog,
)


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 20
    keep: int = 3
    seed: int = 0
    log_every: int = 10
    max_restarts: int = 3


class Trainer:
    def __init__(self, arch: ArchConfig, pcfg: ParallelConfig, mesh,
                 opt_cfg: adamw.AdamWConfig, data_cfg: DataConfig,
                 tcfg: TrainerConfig,
                 injector: Optional[FailureInjector] = None,
                 lr_schedule=None):
        self.arch, self.pcfg, self.mesh = arch, pcfg, mesh
        self.opt_cfg, self.data_cfg, self.tcfg = opt_cfg, data_cfg, tcfg
        self.injector = injector
        self.lr_schedule = lr_schedule
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep)
        self.watchdog = StragglerWatchdog()
        self.heartbeat = Heartbeat()
        self._preempted = False
        # axis -> rank-id-aware degraded Communicator (built up by
        # _shrink_to_survivors as failures accumulate; absent = intact)
        self._axis_comms: dict = {}
        # per-step structured metrics (one `record()` per training step;
        # the returned log rows are views of the same records)
        self.metrics = telemetry.MetricsRegistry()
        self.ts = stages.build_train_step(arch, pcfg, mesh, opt_cfg,
                                          lr_schedule)

    # -- state ---------------------------------------------------------------
    def _fresh_state(self):
        params = stages.init_params(self.arch, self.mesh, self.ts.ctx.tp,
                                    seed=self.tcfg.seed)
        opt = adamw.adamw_init(params)
        opt = jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(self.mesh, s)),
            opt, self.ts.opt_specs)
        return params, opt, 0

    def _state_tree(self, params, opt):
        return {"params": params, "opt": opt}

    def _state_specs(self):
        return {"params": self.ts.specs, "opt": self.ts.opt_specs}

    def restore_or_init(self):
        got = self.ckpt.restore_latest(
            self._shape_tree(), self._state_specs(), self.mesh)
        if got is None:
            return self._fresh_state()
        step, tree, _ = got
        return tree["params"], tree["opt"], step + 1

    def _shape_tree(self):
        params = stages.param_shapes(self.arch, self.mesh, self.ts.ctx.tp)
        # opt shapes mirror params in fp32
        def leaf(sd):
            return {
                "master": jax.ShapeDtypeStruct(sd.shape, jnp.float32),
                "m": jax.ShapeDtypeStruct(sd.shape, jnp.float32),
                "v": jax.ShapeDtypeStruct(sd.shape, jnp.float32),
            }
        opt = {"leaves": jax.tree.map(
                   leaf, params,
                   is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)),
               "count": jax.ShapeDtypeStruct((), jnp.int32)}
        return {"params": params, "opt": opt}

    # -- loop ----------------------------------------------------------------
    def _install_signals(self):
        def handler(signum, frame):
            self._preempted = True
        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass  # non-main thread (tests)

    def run(self):
        self._install_signals()
        restarts = 0
        log = []
        state = None
        while True:
            try:
                log.extend(self._run_once(state))
                return log
            except SimulatedDeviceFailure as e:
                restarts += 1
                if restarts > self.tcfg.max_restarts:
                    raise
                log.append({"event": "failure", "error": str(e),
                            "restart": restarts})
                # checkpoint-restart: fall through and resume from latest
                state = None
                continue
            except RankFailure as e:
                restarts += 1
                if restarts > self.tcfg.max_restarts:
                    raise
                # shrink-and-continue: no checkpoint restore — the mesh
                # loses the dead rank, the step is rebuilt on the
                # degraded mesh, and the IN-MEMORY state carries on
                log.extend(getattr(e, "partial_log", None) or [])
                state = self._shrink_to_survivors(e)
                comm = self._axis_comms.get(e.axis)
                log.append({"event": "rank_failure", "error": str(e),
                            "rank": e.rank, "axis": e.axis,
                            "survivors": list(comm.global_ranks)
                            if comm is not None else [],
                            "mesh_shape": dict(self.mesh.shape),
                            "restart": restarts})
                continue

    def _shrink_to_survivors(self, failure: RankFailure):
        """Checkpoint-restart-free recovery from a dead rank.

        The degraded-communicator path end to end: drop the dead rank's
        devices from the mesh along the failed axis, rebuild the train
        step (its engine's selector replans every collective on the
        shrunk communicators), and re-place the in-memory params/opt on
        the surviving devices. Returns the (params, opt, step) state the
        next `_run_once` continues from — training never goes back to a
        checkpoint.

        The failed rank's POSITION along the axis is removed — not a
        prefix — and the surviving original rank ids are tracked in a
        rank-id-aware degraded `Communicator` (`without_ranks`, chained
        across repeated failures), so a mid-mesh failure leaves every
        non-contiguous survivor holding its own devices; the host
        round-trip in `place` then re-shards state onto exactly those
        survivors."""
        import numpy as np
        from repro.core.topology import Communicator
        if failure.state is None:
            raise failure  # failed outside the step loop: nothing to save
        if self.mesh.shape[failure.axis] <= 1:
            raise failure  # no survivors to shrink onto
        params, opt, step = failure.state
        idx = self.mesh.axis_names.index(failure.axis)
        pos = failure.rank % self.mesh.shape[failure.axis]
        comm = self._axis_comms.get(failure.axis)
        if comm is None:
            comm = Communicator(axis=failure.axis,
                                size=self.mesh.shape[failure.axis])
        self._axis_comms[failure.axis] = comm.without_ranks([pos])
        devices = np.delete(np.asarray(self.mesh.devices), pos, axis=idx)
        self.mesh = jax.sharding.Mesh(devices, self.mesh.axis_names)
        self.ts = stages.build_train_step(self.arch, self.pcfg, self.mesh,
                                          self.opt_cfg, self.lr_schedule)

        def place(tree, specs):
            return jax.tree.map(
                lambda x, s: jax.device_put(
                    jax.device_get(x), NamedSharding(self.mesh, s)),
                tree, specs)

        return (place(params, self.ts.specs),
                place(opt, self.ts.opt_specs), step)

    def _queue_stats(self):
        """Offload-queue telemetry from the step's CollectiveEngine.

        The gradient sync issues its bucket allreduces through the
        engine's request queue (stages.grad_sync / itree_allreduce);
        issuing happens at TRACE time, so these counters move on the
        first step (and on any retrace) and then hold — logged so runs
        record how many collectives rode the queue and how many
        coalesced into bucketed programs.

        When the engine created no queue (grad sync ran blocking, or
        there was nothing to sync), the keys are still present with
        explicit `None` values — a log row missing queue numbers means
        "no queue existed", never a silent drop."""
        q = self.ts.ctx.engine._queue
        if q is None:
            return {"queue_issued": None, "queue_coalesced": None,
                    "grad_sync_makespan_s": None}
        out = {"queue_issued": q.stats["issued"],
               "queue_coalesced": q.stats["coalesced_requests"]}
        # the mesh-level (contention-aware) price of the step's gradient
        # exchange, recorded at trace time by stages.grad_sync
        ms = self.ts.ctx.engine.stats.get("grad_sync_makespan_s")
        if ms is not None:
            out["grad_sync_makespan_s"] = ms
        return out

    def _run_once(self, state=None):
        if state is not None:
            params, opt, start = state  # shrink-and-continue resume
        else:
            params, opt, start = self.restore_or_init()
        loader = make_loader(self.data_cfg, self.arch, start_step=start)
        log = []
        try:
            for step, batch in loader:
                if step >= self.tcfg.total_steps or self._preempted:
                    break
                if self.injector:
                    try:
                        self.injector.check(step)
                    except RankFailure as e:
                        # attach the live state (and the metrics logged
                        # so far — they will not be re-run) so recovery
                        # needs no checkpoint restore
                        e.state = (params, opt, step)
                        e.partial_log = log
                        raise
                t0 = time.perf_counter()
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                params, opt, metrics = self.ts.fn(
                    params, opt, batch, jnp.int32(step))
                metrics = {k: float(v) for k, v in metrics.items()}
                dt = time.perf_counter() - t0
                self.heartbeat.beat()
                z = self.watchdog.observe(step, dt)
                rec = {"step": step, "dt": dt, **metrics,
                       **self._queue_stats()}
                if z is not None:
                    rec["straggler_z"] = z
                self.metrics.record(**rec)
                log.append(rec)
                if (step + 1) % self.tcfg.ckpt_every == 0:
                    self.ckpt.save(step, self._state_tree(params, opt),
                                   self._state_specs())
            # final blocking checkpoint (preemption-safe shutdown)
            if log:
                self.ckpt.save(log[-1]["step"],
                               self._state_tree(params, opt),
                               self._state_specs(), blocking=True)
        finally:
            loader.close()
            self.ckpt.wait()
        return log
