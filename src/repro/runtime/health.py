"""Health machinery: straggler detection + failure injection.

On a real 1000-node fleet, stragglers (thermal throttling, failing HBM,
noisy neighbours) and hard failures dominate MTBF. The runtime pieces that
do not need real hardware to be real code:

  StragglerWatchdog  per-step wall-time EWMA + z-score detector; fires a
                     configurable mitigation callback (alert / rescale).
  FailureInjector    deterministic chaos hook used by the integration
                     tests: raises a simulated device failure at chosen
                     steps to exercise checkpoint-restart.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Optional


class SimulatedDeviceFailure(RuntimeError):
    pass


class RankFailure(RuntimeError):
    """A peer rank died mid-run (the grad-sync collective's PEER_FAILED
    surfaced to the trainer). Unlike SimulatedDeviceFailure — which is
    recovered by checkpoint-restart — a rank failure is recoverable
    WITHOUT a restore: the trainer shrinks the mesh to the survivors
    along `axis`, replans, and continues from in-memory state."""

    def __init__(self, msg, *, rank: int, axis: str = "data"):
        super().__init__(msg)
        self.rank = rank
        self.axis = axis
        #: (params, opt, step) attached by the trainer at the failure
        #: point so shrink-and-continue resumes without a checkpoint
        self.state = None


@dataclasses.dataclass
class StragglerWatchdog:
    """EWMA step-time monitor. z > threshold for `patience` consecutive
    steps => mitigation(step, z)."""

    alpha: float = 0.1
    threshold: float = 3.0
    patience: int = 3
    warmup: int = 5
    mitigation: Optional[Callable] = None

    _mean: float = 0.0
    _var: float = 0.0
    _n: int = 0
    _strikes: int = 0
    events: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> Optional[float]:
        """Feed one step duration; returns z-score if flagged."""
        self._n += 1
        if self._n <= self.warmup:
            # prime the EWMA
            self._mean = dt if self._n == 1 else \
                (1 - self.alpha) * self._mean + self.alpha * dt
            self._var = max(self._var, (dt - self._mean) ** 2)
            return None
        std = math.sqrt(self._var) if self._var > 0 else 1e-9
        z = (dt - self._mean) / std
        self._mean = (1 - self.alpha) * self._mean + self.alpha * dt
        self._var = (1 - self.alpha) * self._var \
            + self.alpha * (dt - self._mean) ** 2
        if z > self.threshold:
            self._strikes += 1
            if self._strikes >= self.patience:
                self.events.append((step, z))
                if self.mitigation:
                    self.mitigation(step, z)
                self._strikes = 0
                return z
        else:
            self._strikes = 0
        return None


@dataclasses.dataclass
class FailureInjector:
    """Raise SimulatedDeviceFailure at the given steps (once each).

    `rank_fail_at` additionally injects dead-RANK failures: (step, rank)
    pairs raise `RankFailure` at that step, once each — the chaos hook
    behind the trainer's shrink-and-continue path."""

    fail_at: tuple = ()
    rank_fail_at: tuple = ()
    axis: str = "data"
    fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise SimulatedDeviceFailure(
                f"injected chip failure at step {step}")
        for (s, rank) in self.rank_fail_at:
            if s == step and ("rank", s) not in self.fired:
                self.fired.add(("rank", s))
                raise RankFailure(
                    f"injected rank {rank} loss at step {step}",
                    rank=rank, axis=self.axis)


class Heartbeat:
    """Liveness file a cluster supervisor would watch (touch per step)."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.last = 0.0

    def beat(self):
        self.last = time.time()
        if self.path:
            with open(self.path, "w") as f:
                f.write(str(self.last))
