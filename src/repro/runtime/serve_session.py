"""Serving session: prefill -> decode cache handoff.

prefill emits layer-stacked caches in a uniform full-prompt-length layout
(scan-friendly); decode wants per-layer caches at s_max with SWA windows
rolled. The conversion works on GLOBAL array views (device_get ->
rearrange -> device_put with the decode specs), which is exactly what a
serving frontend does between the two compiled programs.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.configs.base import ArchConfig, ParallelConfig
from repro.models.blocks import window_per_layer
from repro.models.serve import layer_cache_len
from repro.parallel import stages


def convert_prefill_caches(prefill_caches, cfg: ArchConfig,
                           pcfg: ParallelConfig, mesh, tp: int,
                           batch: int, s_prompt: int, s_max: int,
                           s_enc: int = 0):
    """Rearrange prefill's stacked caches into decode's per-layer layout."""
    windows = window_per_layer(cfg, cfg.n_layers)
    dp = stages.dp_axes(mesh, batch)
    decode_specs = stages.cache_specs(cfg, pcfg, tp, s_max, s_enc=s_enc,
                                      dp=dp)
    host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                        prefill_caches)

    def attn_pair(k_all, v_all, layer):
        length = layer_cache_len(cfg, layer, s_max)
        k_l, v_l = k_all[layer], v_all[layer]        # (B, S_p, KV, hd)
        out_k = np.zeros((batch, length) + k_l.shape[2:], k_l.dtype)
        out_v = np.zeros_like(out_k)
        w = windows[layer]
        if w and w < s_max:
            # rolling window: position p lives at slot p % length
            take = min(length, s_prompt)
            src = k_l[:, s_prompt - take:s_prompt]
            pos = np.arange(s_prompt - take, s_prompt)
            out_k[:, pos % length] = src
            out_v[:, pos % length] = v_l[:, s_prompt - take:s_prompt]
        else:
            out_k[:, :s_prompt] = k_l[:, :s_prompt]
            out_v[:, :s_prompt] = v_l[:, :s_prompt]
        return out_k, out_v

    caches = []
    if cfg.family == "ssm":
        conv_all, state_all = host
        for layer in range(cfg.n_layers):
            caches.append({"conv": conv_all[layer],
                           "state": state_all[layer]})
    elif cfg.family == "hybrid":
        k_all, v_all, conv_all, state_all = host
        for layer in range(cfg.n_layers):
            k, v = attn_pair(k_all, v_all, layer)
            caches.append({"k": k, "v": v, "conv": conv_all[layer],
                           "state": state_all[layer]})
    elif cfg.encoder_layers:
        k_all, v_all, xk_all, xv_all = host
        for layer in range(cfg.n_layers):
            k, v = attn_pair(k_all, v_all, layer)
            caches.append({"k": k, "v": v, "xk": xk_all[layer],
                           "xv": xv_all[layer]})
    else:
        k_all, v_all = host
        for layer in range(cfg.n_layers):
            k, v = attn_pair(k_all, v_all, layer)
            caches.append({"k": k, "v": v})

    return jax.tree.map(
        lambda x, sp: jax.device_put(np.asarray(x),
                                     NamedSharding(mesh, sp)),
        caches, decode_specs,
        is_leaf=lambda x: isinstance(x, np.ndarray))


@dataclasses.dataclass
class ServeSession:
    """Compiled prefill + decode pair with automatic cache handoff."""

    cfg: ArchConfig
    pcfg: ParallelConfig
    mesh: object
    tp: int
    batch: int
    s_prompt: int
    s_max: int

    def __post_init__(self):
        # handoff requires the uniform (non-quantized) cache dtype
        assert self.pcfg.kv_cache_dtype == "param", \
            "int8 caches are decode-internal; prefill emits param dtype"
        self.prefill_fn, _, _, _ = stages.build_prefill(
            self.cfg, self.pcfg, self.mesh, self.batch, self.s_prompt)
        self.decode_fn, _, _, _ = stages.build_decode_step(
            self.cfg, self.pcfg, self.mesh, s_max=self.s_max,
            global_batch=self.batch)

    def generate(self, params, tokens, n_new: int):
        """tokens: (B, s_prompt) -> (B, n_new) greedy continuation."""
        nxt, pf_caches = self.prefill_fn(params, {"tokens": tokens})
        caches = convert_prefill_caches(
            pf_caches, self.cfg, self.pcfg, self.mesh, self.tp,
            self.batch, self.s_prompt, self.s_max)
        out = [np.asarray(nxt)]
        tok = jnp.asarray(np.asarray(nxt)[:, None], jnp.int32)
        for i in range(n_new - 1):
            nxt, caches = self.decode_fn(params, caches, tok,
                                         jnp.int32(self.s_prompt + i))
            out.append(np.asarray(nxt))
            tok = jnp.asarray(np.asarray(nxt)[:, None], jnp.int32)
        return np.stack(out, axis=1)
