from repro.runtime.trainer import Trainer, TrainerConfig
from repro.runtime.health import StragglerWatchdog, FailureInjector
from repro.runtime.serve_session import ServeSession, convert_prefill_caches

__all__ = ["Trainer", "TrainerConfig", "StragglerWatchdog",
           "FailureInjector", "ServeSession", "convert_prefill_caches"]
