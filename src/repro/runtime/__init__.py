from repro.runtime.trainer import Trainer, TrainerConfig
from repro.runtime.health import (
    FailureInjector, RankFailure, SimulatedDeviceFailure, StragglerWatchdog,
)
from repro.runtime.serve_session import ServeSession, convert_prefill_caches

__all__ = ["Trainer", "TrainerConfig", "StragglerWatchdog",
           "FailureInjector", "RankFailure", "SimulatedDeviceFailure",
           "ServeSession", "convert_prefill_caches"]
