"""Step builders: full-mesh shard_map train_step / prefill / decode_step.

The whole step runs inside ONE shard_map over the production mesh
(manual-GSPMD): every collective — FSDP gathers, TP reductions, EP
all-to-alls, DP gradient sync — is issued by the CollectiveEngine
(backend='microcode' = the paper's CCLO; 'native' = XLA's built-ins, the
software-MPI baseline).

Gradient sync rule (validated in tests/test_grad_semantics.py): a param's
gradient must be psum'd over every mesh axis absent from its PartitionSpec.
Leaves are bucketed by their missing-axis set and synced with ONE fused
engine allreduce per bucket (gradient bucketing), optionally
int8/bf16-compressed (the paper's unary streaming plugin as a distributed-
optimization trick). By default the buckets go through the engine's
non-blocking request queue (`itree_allreduce`): all groups issue before
any waits, the paper's offload-engine enqueue-then-overlap pattern
(`ParallelConfig.async_grad_sync`).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from repro.core.compat import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ParallelConfig
from repro.core.engine import CollectiveEngine
from repro.models import lm as lm_mod
from repro.models import serve as serve_mod
from repro.models.common import Builder, dt
from repro.optim import adamw
from repro.parallel.ops import ParCtx, spec_axes


def make_ctx(cfg: ArchConfig, pcfg: ParallelConfig, mesh) -> ParCtx:
    engine = CollectiveEngine(mesh, backend=pcfg.backend,
                              use_pallas=pcfg.use_pallas)
    return ParCtx(engine=engine, pcfg=pcfg, mesh=mesh)


# --------------------------------------------------------------------------
# Params in three modes
# --------------------------------------------------------------------------

def _drop_data_axis(spec: P) -> P:
    return P(*(None if e == "data" else e for e in spec))


def param_specs(cfg: ArchConfig, tp: int, serve: bool = False):
    specs = lm_mod.model_params(Builder("spec"), cfg, tp)
    if serve:
        # serving layout: weights replicated over 'data' (pure TP) — no
        # ZeRO-3 gathers on the token path
        specs = jax.tree.map(_drop_data_axis, specs,
                             is_leaf=lambda x: isinstance(x, P))
    return specs


def param_shapes(cfg: ArchConfig, mesh, tp: int, dtype=None,
                 serve: bool = False):
    b = Builder("shape", mesh=mesh, dtype=dtype or dt(cfg.param_dtype))
    shapes = lm_mod.model_params(b, cfg, tp)
    if serve:
        specs = param_specs(cfg, tp, serve=True)
        shapes = jax.tree.map(
            lambda sd, sp: jax.ShapeDtypeStruct(
                sd.shape, sd.dtype,
                sharding=NamedSharding(mesh, sp)),
            shapes, specs,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    return shapes


def init_params(cfg: ArchConfig, mesh, tp: int, seed: int = 0):
    """Real init (host-side, then device_put with the spec sharding)."""
    b = Builder("init", key=jax.random.PRNGKey(seed),
                dtype=dt(cfg.param_dtype))
    params = lm_mod.model_params(b, cfg, tp)
    specs = param_specs(cfg, tp)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
        params, specs, is_leaf=lambda x: isinstance(x, jnp.ndarray))


# --------------------------------------------------------------------------
# Gradient sync
# --------------------------------------------------------------------------

def grad_sync(grads, specs, ctx: ParCtx,
              compression: Optional[str] = None,
              use_queue: bool = True):
    """Bucketed, engine-routed gradient synchronization.

    With `use_queue` (`ParallelConfig.async_grad_sync`), every sync
    group's bucketed allreduces are ISSUED into the engine's request
    queue first (`itree_allreduce` — the non-blocking CCLO offload
    path) and only then waited: all gradient buckets sit in the queue
    together, so small same-dtype buckets coalesce into one program and
    independent buckets drain back-to-back without per-call re-entry.
    The queue's coalescing eligibility rule makes this bitwise-identical
    to the blocking path.

    Returns (synced grads, psum-corrected local sum-of-squares for the
    global clip norm: each leaf's contribution divided by its replication
    factor so one allreduce over the full mesh yields the true norm).
    """
    mesh_axes = [a for a in ctx.mesh.axis_names if ctx.mesh.shape[a] > 1]
    flat, treedef = jax.tree.flatten_with_path(grads)
    spec_flat = {tuple(p): s for p, s in jax.tree.flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]}

    buckets: dict = {}
    for path, leaf in flat:
        spec = spec_flat[tuple(path)]
        missing = tuple(a for a in mesh_axes if a not in spec_axes(spec))
        buckets.setdefault(missing, []).append((path, leaf))

    # issue phase: enqueue every sync group's bucket collectives before
    # materializing any (the backward walk's grads are all live here, so
    # the whole gradient exchange is outstanding at once — the paper's
    # enqueue-then-overlap offload pattern)
    tickets = {}
    for missing, entries in buckets.items():
        if not missing:
            continue
        leaves = [l for _, l in entries]
        # fastest (ICI) axes first, pod (DCN) last. A two-axis group
        # (("data", "pod") — the cross-pod data-parallel bucket) folds
        # into ONE hierarchical request over the product communicator:
        # a single two-level program whose DCN phase carries 1/|data|
        # of the bucket bytes (engine.allreduce_multi / issue_multi).
        order = [a for a in ("data", "model") if a in missing] + \
                [a for a in missing if a not in ("data", "model")]
        if use_queue:
            tickets[missing] = ctx.engine.itree_allreduce(
                leaves, order, compression=compression)
        else:
            tickets[missing] = ctx.engine.tree_allreduce(
                leaves, order, compression=compression)

    if use_queue and tickets:
        # mesh-level price of the outstanding gradient exchange: every
        # sync group's queue composed over the shared fabrics (the
        # contention-aware view, not per-axis optimism). Trace-time
        # telemetry off static shapes — no tracers involved; the trainer
        # surfaces it per step (`Trainer._queue_stats`).
        from repro.core.mesh_cost import MeshMakespan
        ctx.engine.metrics.set("grad_sync_makespan_s",
                               MeshMakespan.of(ctx.engine.queue).total())

    out = {}
    sq = jnp.zeros((), jnp.float32)
    for missing, entries in buckets.items():
        repl = 1
        for a in missing:
            repl *= ctx.mesh.shape[a]
        if missing:
            t = tickets[missing]
            synced = t.wait() if use_queue else t
        else:
            synced = [l for _, l in entries]
        for (path, _), s in zip(entries, synced):
            out[tuple(path)] = s
            sq = sq + jnp.sum(jnp.square(s.astype(jnp.float32))) / repl

    ordered = [out[tuple(p)] for p, _ in flat]
    return jax.tree.unflatten(treedef, ordered), sq


# --------------------------------------------------------------------------
# Train step
# --------------------------------------------------------------------------

@dataclasses.dataclass
class TrainStep:
    fn: object            # jitted shard_map step
    ctx: ParCtx
    specs: object         # param PartitionSpec tree
    opt_specs: object
    batch_spec: object


def build_train_step(cfg: ArchConfig, pcfg: ParallelConfig, mesh,
                     opt_cfg: adamw.AdamWConfig,
                     lr_schedule=None) -> TrainStep:
    ctx = make_ctx(cfg, pcfg, mesh)
    tp = ctx.tp
    specs = param_specs(cfg, tp)
    ospecs = adamw.opt_specs(specs)
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = lm_mod.batch_specs(cfg, "train", dp=dp)

    def step(params, opt_state, batch, step_idx):
        def lf(p, mb):
            return lm_mod.loss_fn(p, mb, cfg, ctx)

        k = pcfg.microbatches
        if k <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                lf, has_aux=True)(params, batch)
        else:
            # gradient accumulation: per-microbatch backward inside the
            # scan body (no cross-microbatch residuals), grads averaged
            def split(leaf):
                b = leaf.shape[0]
                return leaf.reshape((k, b // k) + leaf.shape[1:])

            mbs = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                g_acc, l_acc, m_acc = carry
                (l, m), g = jax.value_and_grad(lf, has_aux=True)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                m_acc = jax.tree.map(lambda a, b: a + b, m_acc, m)
                return (g_acc, l_acc + l, m_acc), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            m0 = {"ce_mean": jnp.zeros((), jnp.float32),
                  "aux": jnp.zeros((), jnp.float32)}
            (grads, loss, metrics), _ = jax.lax.scan(
                acc_body, (g0, jnp.zeros((), jnp.float32), m0), mbs)
            grads = jax.tree.map(lambda g: g / k, grads)
            loss = loss / k
            metrics = jax.tree.map(lambda m: m / k, metrics)
        grads, sq_local = grad_sync(grads, specs, ctx,
                                    compression=pcfg.grad_compression,
                                    use_queue=pcfg.async_grad_sync)
        # global clip norm: one scalar allreduce over the whole mesh
        axes = [a for a in mesh.axis_names if mesh.shape[a] > 1]
        sq = sq_local
        for a in axes:
            sq = ctx.engine.allreduce(sq, a)
        gnorm = jnp.sqrt(sq)
        scale = jnp.minimum(1.0, opt_cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

        lr_scale = lr_schedule(step_idx) if lr_schedule else 1.0
        cfg_noclip = dataclasses.replace(opt_cfg, grad_clip=1e30)
        opt_state, _ = adamw.adamw_update(cfg_noclip, grads, opt_state,
                                          lr_scale=lr_scale)
        params = adamw.apply_updates(opt_state, dt(cfg.param_dtype))
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["loss"] = loss
        return params, opt_state, metrics

    mapped = shard_map(
        step, mesh=mesh,
        in_specs=(specs, ospecs, bspec, P()),
        out_specs=(specs, ospecs, jax.tree.map(lambda _: P(), {
            "ce_mean": 0, "aux": 0, "grad_norm": 0, "loss": 0})),
        check_vma=False)
    fn = jax.jit(mapped, donate_argnums=(0, 1))
    return TrainStep(fn=fn, ctx=ctx, specs=specs, opt_specs=ospecs,
                     batch_spec=bspec)


# --------------------------------------------------------------------------
# Serve steps
# --------------------------------------------------------------------------

def dp_axes(mesh, global_batch: int):
    """DP sharding axes for a batch dim; None (replicate) when the batch
    is smaller than the DP group (B=1 long-context decode)."""
    axes = tuple(a for a in ("pod", "data")
                 if a in mesh.axis_names and mesh.shape[a] > 1)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return axes if axes and global_batch % n == 0 else None


def build_prefill(cfg: ArchConfig, pcfg: ParallelConfig, mesh,
                  global_batch: int, seq_len: int):
    pcfg = dataclasses.replace(pcfg, serving=True)
    ctx = make_ctx(cfg, pcfg, mesh)
    specs = param_specs(cfg, ctx.tp, serve=True)
    dp = dp_axes(mesh, global_batch)
    bspec = lm_mod.batch_specs(cfg, "prefill", dp=dp)
    cspec = serve_mod.prefill_cache_specs(cfg, pcfg, ctx.tp, seq_len, dp=dp)

    def pf(params, batch):
        return serve_mod.prefill(params, batch, cfg, ctx)

    mapped = shard_map(pf, mesh=mesh, in_specs=(specs, bspec),
                       out_specs=(P(dp), cspec), check_vma=False)
    return jax.jit(mapped), ctx, specs, bspec


def cache_specs(cfg: ArchConfig, pcfg: ParallelConfig, tp: int,
                s_max: int, s_enc: int = 0, dp=("pod", "data")):
    b = Builder("spec")
    return serve_mod.make_cache(b, cfg, tp, 0, s_max, pcfg, s_enc=s_enc,
                                dp=dp)


def cache_shapes(cfg: ArchConfig, pcfg: ParallelConfig, mesh, tp: int,
                 batch: int, s_max: int, s_enc: int = 0, dp=("pod", "data")):
    b = Builder("shape", mesh=mesh, dtype=dt(cfg.param_dtype))
    return serve_mod.make_cache(b, cfg, tp, batch, s_max, pcfg, s_enc=s_enc,
                                dp=dp)


def init_cache(cfg: ArchConfig, pcfg: ParallelConfig, mesh, tp: int,
               batch: int, s_max: int, s_enc: int = 0):
    dp = dp_axes(mesh, batch)
    b = Builder("init", key=jax.random.PRNGKey(0), dtype=dt(cfg.param_dtype))
    cache = serve_mod.make_cache(b, cfg, tp, batch, s_max, pcfg,
                                 s_enc=s_enc, dp=dp)
    cspecs = cache_specs(cfg, pcfg, tp, s_max, s_enc=s_enc, dp=dp)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
        cache, cspecs, is_leaf=lambda x: isinstance(x, jnp.ndarray))


def build_decode_step(cfg: ArchConfig, pcfg: ParallelConfig, mesh,
                      s_max: int, global_batch: int, s_enc: int = 0):
    pcfg_d = dataclasses.replace(pcfg, sequence_parallel=False,
                                 serving=True)
    ctx = make_ctx(cfg, pcfg_d, mesh)
    specs = param_specs(cfg, ctx.tp, serve=True)
    dp = dp_axes(mesh, global_batch)
    cspecs = cache_specs(cfg, pcfg_d, ctx.tp, s_max, s_enc=s_enc, dp=dp)

    def dstep(params, caches, tokens, pos):
        return serve_mod.decode_step(params, caches, tokens, pos, cfg, ctx,
                                     s_max)

    mapped = shard_map(
        dstep, mesh=mesh,
        in_specs=(specs, cspecs, P(dp, None), P()),
        out_specs=(P(dp), cspecs),
        check_vma=False)
    return jax.jit(mapped, donate_argnums=(1,)), ctx, specs, cspecs
