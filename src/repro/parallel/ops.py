"""Sharded linear algebra through the CollectiveEngine.

Every TP/FSDP communication pattern used by the models lives here, so the
collective engine (paper contribution) is the single chokepoint for all
model communication:

  gather_fsdp          ZeRO-3 weight all-gather at use (VJP = reduce-scatter
                       over the same ring — verified to produce data-summed
                       shard gradients)
  row_parallel_finish  psum (baseline) or seq reduce-scatter (SP)
  sp_allgather_seq     SP re-gather of sequence-sharded activations
  col_parallel_matmul  optionally the streaming collective matmul

Gradient semantics (empirically validated, see tests/test_grad_semantics.py):
shard_map autodiff differentiates the SUM of per-rank local losses, so a
loss replicated over the TP axis must be pre-scaled by 1/tp_size, and each
param's gradient must be psum'd over every mesh axis absent from its
PartitionSpec (runtime/grad_sync).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ParallelConfig
from repro.core.engine import CollectiveEngine


@dataclasses.dataclass
class ParCtx:
    """Per-step parallel context threaded through all layers."""

    engine: CollectiveEngine
    pcfg: ParallelConfig
    mesh: jax.sharding.Mesh

    @property
    def tp(self) -> int:
        return self.mesh.shape.get(self.pcfg.tp_axis, 1)

    @property
    def fsdp(self) -> int:
        if self.pcfg.serving:
            return 1  # serving layout: weights replicated over 'data'
        return self.mesh.shape.get(self.pcfg.fsdp_axis, 1)

    @property
    def tp_axis(self) -> str:
        return self.pcfg.tp_axis

    @property
    def fsdp_axis(self) -> str:
        return self.pcfg.fsdp_axis

    def tp_rank(self):
        return jax.lax.axis_index(self.pcfg.tp_axis) if self.tp > 1 else 0

    # -- FSDP ---------------------------------------------------------------
    def gather_fsdp(self, w, dim: int = 0):
        """All-gather a ZeRO-3-sharded weight along `dim` for use."""
        if self.fsdp == 1:
            return w
        if dim != 0:
            w = jnp.moveaxis(w, dim, 0)
        shape = (w.shape[0] * self.fsdp,) + w.shape[1:]
        out = self.engine.allgather(w, self.fsdp_axis).reshape(shape)
        if dim != 0:
            out = jnp.moveaxis(out, 0, dim)
        return out

    # -- TP epilogues/prologues ----------------------------------------------
    def row_parallel_finish(self, y_partial, seq_dim: int = 1):
        """Finish a row-parallel matmul: psum over TP, or — under sequence
        parallelism — reduce-scatter the sequence dim (engine ring RS)."""
        if self.tp == 1:
            return y_partial
        if self.pcfg.sequence_parallel and y_partial.shape[seq_dim] % self.tp == 0:
            y = jnp.moveaxis(y_partial, seq_dim, 0)
            lead = y.shape[0]
            flat = y.reshape(lead, -1)
            shard = self.engine.reduce_scatter(flat.reshape(-1), self.tp_axis)
            y = shard.reshape(lead // self.tp, *y.shape[1:])
            return jnp.moveaxis(y, 0, seq_dim)
        return self.engine.allreduce(y_partial, self.tp_axis)

    def sp_allgather_seq(self, x, seq_dim: int = 1):
        """SP prologue: re-gather sequence-sharded activations over TP."""
        if self.tp == 1 or not self.pcfg.sequence_parallel:
            return x
        y = jnp.moveaxis(x, seq_dim, 0)
        flat = self.engine.allgather(y, self.tp_axis)
        y = flat.reshape((self.tp * y.shape[0],) + y.shape[1:])
        return jnp.moveaxis(y, 0, seq_dim)

    def dense(self, x, w, fsdp_dim: int = 0):
        """x @ gather(w); the workhorse projection."""
        w = self.gather_fsdp(w, fsdp_dim)
        return jnp.einsum("...d,df->...f", x, w.astype(x.dtype))

    def col_parallel_matmul(self, x, w, fsdp_dim: int = 0, seq_dim: int = 1,
                            pregathered: bool = False):
        """Column-parallel projection. Under SP + collective_matmul, the
        sequence all-gather is fused with the matmul (streaming collective,
        paper Listing 2); otherwise gather-then-matmul. `pregathered`
        skips the FSDP gather (fused multi-projection weights)."""
        if not pregathered:
            w = self.gather_fsdp(w, fsdp_dim)
        if (self.pcfg.sequence_parallel and self.pcfg.collective_matmul
                and self.tp > 1):
            b = x.shape[0]
            xt = jnp.moveaxis(x, seq_dim, 1) if seq_dim != 1 else x
            s_l, d = xt.shape[1], xt.shape[-1]
            # fold batch into rows rank-consistently: rows cycle seq-major
            x2 = xt.reshape(b * s_l, d)
            y2 = self.engine.allgather_matmul(x2, w.astype(x.dtype),
                                              self.tp_axis)
            y = y2.reshape(self.tp, b, s_l, -1).transpose(1, 0, 2, 3)
            y = y.reshape(b, self.tp * s_l, -1)
            return jnp.moveaxis(y, 1, seq_dim) if seq_dim != 1 else y
        x = self.sp_allgather_seq(x, seq_dim)
        return jnp.einsum("...d,df->...f", x, w.astype(x.dtype))


def spec_axes(spec: P) -> set:
    """Mesh axes appearing anywhere in a PartitionSpec."""
    axes = set()
    for entry in spec:
        if entry is None:
            continue
        if isinstance(entry, (tuple, list)):
            axes.update(entry)
        else:
            axes.add(entry)
    return axes
