# stages is intentionally NOT imported here: it pulls in the model zoo and
# would create a models <-> parallel import cycle. Import it directly:
# `from repro.parallel import stages`.
from repro.parallel.ops import ParCtx, spec_axes

__all__ = ["ParCtx", "spec_axes"]
