"""Data pipeline: deterministic, resumable, sharded, prefetching.

Production requirements served here:
  * deterministic per-step batches keyed by (seed, step) — a restarted or
    rescheduled job consumes the exact same token stream (resume-exact);
  * host sharding: each process loads only its data-parallel slice
    (process_index/process_count plumbing; single-process in this
    container but the code path is the real one);
  * sources: synthetic LM stream (hash-based, no files) and a memmapped
    token file (the on-disk format real corpora would use);
  * background prefetch (double buffering) so host data work overlaps
    device steps.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    seed: int = 0
    source: str = "synthetic"      # 'synthetic' | 'memmap'
    memmap_path: Optional[str] = None
    prefetch: int = 2


class SyntheticLM:
    """Deterministic pseudo-corpus: batch(step) is a pure function.

    Uses Philox counter RNG keyed by (seed, step) so any step's batch can
    be regenerated in O(1) — the property the resume path relies on.
    """

    def __init__(self, cfg: DataConfig, arch: ArchConfig):
        self.cfg = cfg
        self.arch = arch

    def batch_at(self, step: int, lo: int, hi: int):
        """Rows [lo, hi) of the global batch at `step`."""
        rng = np.random.Generator(
            np.random.Philox(key=self.cfg.seed, counter=[step, 0, 0, 0]))
        v = self.arch.vocab_size
        s = self.cfg.seq_len
        tokens = rng.integers(0, v, (self.cfg.global_batch, s + 1),
                              dtype=np.int32)
        out = {"tokens": tokens[lo:hi, :-1], "labels": tokens[lo:hi, 1:]}
        if self.arch.family == "vlm":
            out["vis_embed"] = rng.standard_normal(
                (hi - lo, self.arch.n_vis_tokens, self.arch.d_model),
                dtype=np.float32)
        if self.arch.encoder_layers:
            out["frames"] = 0.1 * rng.standard_normal(
                (hi - lo, s, self.arch.d_model), dtype=np.float32)
        return out


class MemmapTokens:
    """Flat .bin int32 token file; sequence i = tokens[i*(S+1):(i+1)*(S+1)].

    Step -> sequence mapping is a fixed permutation-free stride (epoch
    wraps), so resume needs only the step counter.
    """

    def __init__(self, cfg: DataConfig, arch: ArchConfig):
        assert cfg.memmap_path, "memmap source needs a path"
        self.cfg = cfg
        self.arch = arch
        self.tokens = np.memmap(cfg.memmap_path, dtype=np.int32, mode="r")
        self.seqs = len(self.tokens) // (cfg.seq_len + 1)
        if self.seqs < cfg.global_batch:
            raise ValueError("corpus smaller than one global batch")

    def batch_at(self, step: int, lo: int, hi: int):
        s = self.cfg.seq_len
        base = (step * self.cfg.global_batch) % self.seqs
        rows = [(base + i) % self.seqs for i in range(lo, hi)]
        arr = np.stack([
            self.tokens[r * (s + 1):(r + 1) * (s + 1)] for r in rows])
        return {"tokens": arr[:, :-1].astype(np.int32),
                "labels": arr[:, 1:].astype(np.int32)}


class ShardedLoader:
    """Process-sharded, prefetching iterator with exact resume."""

    def __init__(self, source, cfg: DataConfig, start_step: int = 0,
                 process_index: int = 0, process_count: int = 1):
        self.source = source
        self.cfg = cfg
        self.step = start_step
        per = cfg.global_batch // process_count
        self.lo = process_index * per
        self.hi = self.lo + per
        self._q: queue.Queue = queue.Queue(maxsize=cfg.prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.source.batch_at(step, self.lo, self.hi)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.5)
                    step += 1
                    break
                except queue.Full:
                    continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step, batch = self._q.get()
        self.step = step + 1
        return step, batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass


def make_loader(cfg: DataConfig, arch: ArchConfig, start_step: int = 0,
                process_index: int = 0, process_count: int = 1):
    src = {"synthetic": SyntheticLM, "memmap": MemmapTokens}[cfg.source](
        cfg, arch)
    return ShardedLoader(src, cfg, start_step, process_index, process_count)
