from repro.data.pipeline import (
    DataConfig, SyntheticLM, MemmapTokens, ShardedLoader, make_loader,
)

__all__ = ["DataConfig", "SyntheticLM", "MemmapTokens", "ShardedLoader",
           "make_loader"]
