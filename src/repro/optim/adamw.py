"""AdamW with fp32 master weights, built from scratch (no optax).

Mixed precision: params may live in bf16; the optimizer state holds fp32
master copies plus fp32 (m, v). All state pytrees mirror the param tree, so
they inherit the params' FSDP/TP sharding specs unchanged — optimizer
memory scales 1/(fsdp*tp) like the params (ZeRO-1 comes for free from the
ZeRO-3 layout).

Gradient clipping uses a *global* norm: inside shard_map the local
sum-of-squares must be psum'd over every mesh axis that shards params or
batch; the caller passes that reduction in (engine-aware).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params):
    def init_leaf(p):
        # copy=True: the master must never alias the compute-dtype param
        # buffer (donation would otherwise see the same buffer twice).
        return {
            "master": jnp.array(p, jnp.float32, copy=True),
            "m": jnp.zeros(p.shape, jnp.float32),
            "v": jnp.zeros(p.shape, jnp.float32),
        }
    return {
        "leaves": jax.tree.map(init_leaf, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree, psum_fn: Optional[Callable] = None):
    sq = sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
             for l in jax.tree.leaves(tree))
    if psum_fn is not None:
        sq = psum_fn(sq)
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, max_norm: float,
                        psum_fn: Optional[Callable] = None):
    norm = global_norm(grads, psum_fn)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(cfg: AdamWConfig, grads, state, lr_scale=1.0,
                 psum_fn: Optional[Callable] = None):
    """Returns (new_params_dtype_of_master_cast, new_state, metrics).

    `grads` tree must be float (any precision); `psum_fn` reduces scalars
    across shard groups for the global clip norm.
    """
    count = state["count"] + 1
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip, psum_fn)
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(leaf_state, g):
        m = cfg.b1 * leaf_state["m"] + (1 - cfg.b1) * g
        v = cfg.b2 * leaf_state["v"] + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        master = leaf_state["master"] * (1.0 - lr * cfg.weight_decay) \
            - lr * step
        return {"master": master, "m": m, "v": v}

    new_leaves = jax.tree.map(
        upd, state["leaves"], grads,
        is_leaf=lambda x: isinstance(x, dict) and "master" in x)
    new_state = {"leaves": new_leaves, "count": count}
    return new_state, {"grad_norm": gnorm}


def apply_updates(state, param_dtype):
    """Materialize compute-precision params from fp32 masters."""
    return jax.tree.map(
        lambda l: l["master"].astype(param_dtype), state["leaves"],
        is_leaf=lambda x: isinstance(x, dict) and "master" in x)


def opt_specs(param_specs):
    """Optimizer-state PartitionSpec tree mirroring the params."""
    from jax.sharding import PartitionSpec as P
    leaves = jax.tree.map(
        lambda s: {"master": s, "m": s, "v": s}, param_specs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    return {"leaves": leaves, "count": P()}
