from repro.optim.adamw import (
    AdamWConfig, adamw_init, adamw_update, apply_updates,
    global_norm, clip_by_global_norm,
)
from repro.optim.schedules import cosine_warmup, linear_warmup

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update", "apply_updates",
    "global_norm", "clip_by_global_norm", "cosine_warmup", "linear_warmup",
]
