"""Qwen3-30B-A3B — fine-grained MoE: 128 experts, top-8, expert ffn 768.

[hf:Qwen/Qwen3-30B-A3B; hf] 48L, d 2048, 32H/4KV head 128, vocab 151936.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=0, vocab_size=151936, qk_norm=True,
    n_experts=128, experts_per_token=8, moe_d_ff=768,
    rope_theta=1e6,
    source="hf:Qwen/Qwen3-30B-A3B",
)
