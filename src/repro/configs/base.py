"""Config system: architecture + parallelism + run configs.

Every assigned architecture is an `ArchConfig` in its own module
(src/repro/configs/<id>.py); `get_config(name)` resolves them. The
parallelism/run knobs live in `MeshConfig`/`RunConfig` so the same arch can
be lowered for smoke tests (1 device), benchmarks (8 virtual devices) and
the production dry-run (512 virtual devices) without edits.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

FAMILIES = ("dense", "moe", "ssm", "hybrid", "audio", "vlm")


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int          # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int             # dense-MLP hidden (0 = no dense MLP)
    vocab_size: int
    head_dim: int = 0     # 0 -> d_model // n_heads
    qk_norm: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    # attention variants
    sliding_window: int = 0          # 0 = full attention
    global_attn_layers: tuple = ()   # hybrid: layers that ignore the window
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    # encoder-decoder (0 = decoder-only)
    encoder_layers: int = 0
    # multimodal prefix stub
    n_vis_tokens: int = 0
    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # source provenance (public literature), recorded for the report
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def has_attention(self) -> bool:
        return self.n_heads > 0

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for long_500k decode: SSM state, hybrid, or SWA-bounded."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window > 0

    @property
    def ssm_d_inner(self) -> int:
        return self.d_model * self.ssm_expand

    @property
    def ssm_n_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def n_params(self) -> int:
        """Parameter count (for MODEL_FLOPS = 6*N*D and memory budgets)."""
        d, f, v, hd = self.d_model, self.d_ff, self.vocab_size, self.resolved_head_dim
        per_layer = 0
        if self.has_attention:
            q = d * self.n_heads * hd
            kv = 2 * d * self.n_kv_heads * hd
            o = self.n_heads * hd * d
            per_layer += q + kv + o
        if self.family == "moe":
            per_layer += d * self.n_experts  # router
            per_layer += self.n_experts * 3 * d * self.moe_d_ff
        elif f:
            per_layer += 3 * d * f  # SwiGLU
        if self.family in ("ssm", "hybrid"):
            di, st, nh = self.ssm_d_inner, self.ssm_state, self.ssm_n_heads
            ssm = d * (2 * di + 2 * st + nh)   # in_proj (z,x,B,C,dt)
            ssm += self.ssm_conv * (di + 2 * st)  # conv1d
            ssm += nh * 2                       # A_log, D
            ssm += di * d                       # out_proj
            per_layer += ssm
        per_layer += 2 * d  # norms
        emb = v * d if self.tie_embeddings else 2 * v * d
        total_layers = self.n_layers + self.encoder_layers
        if self.encoder_layers:  # cross-attention in decoder layers
            per_layer_x = 2 * d * self.n_kv_heads * hd + d * self.n_heads * hd \
                + self.n_heads * hd * d + d
            total = (self.n_layers * (per_layer + per_layer_x)
                     + self.encoder_layers * per_layer)
            return total + emb + 2 * d
        return total_layers * per_layer + emb + 2 * d

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed experts)."""
        if self.family != "moe":
            return self.n_params()
        dense = self.n_params()
        unused = (self.n_experts - self.experts_per_token) * \
            3 * self.d_model * self.moe_d_ff * self.n_layers
        return dense - unused


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """Parallelism & perf knobs (the hillclimb levers)."""

    backend: str = "microcode"         # 'microcode' | 'native'
    fsdp_axis: str = "data"            # weight-shard axis
    dp_axes: tuple = ("pod", "data")   # batch axes
    tp_axis: str = "model"
    sequence_parallel: bool = False    # SP norm regions (RS/AG pairs)
    remat: str = "full"                # 'none' | 'full' | 'dots'
    grad_compression: Optional[str] = None  # None | 'int8' | 'bf16'
    collective_matmul: bool = False    # streaming TP matmuls
    attn_q_block: int = 512
    attn_kv_block: int = 1024
    moe_capacity_factor: float = 1.25
    use_pallas: bool = False
    scan_layers: bool = True
    # gradient accumulation: split the per-device batch into k microbatches
    # (scan with per-microbatch backward — activations shrink k x, enabling
    # remat='none' at full-remat memory budgets)
    microbatches: int = 1
    # decode: shard KV-cache sequence over the TP axis + flash-combine
    decode_seq_shard: bool = True
    # serving layout: params replicate over 'data' (no ZeRO-3 gathers on
    # the token path); set automatically by the serve step builders
    serving: bool = False
    # KV-cache storage dtype: 'param' (model dtype) or 'int8' (per-slot
    # symmetric quantization — the paper's unary streaming plugin applied
    # to cache storage; beyond-paper decode-memory optimization)
    kv_cache_dtype: str = "param"
    # gradient sync through the engine's request queue: every bucket's
    # allreduce is ISSUED non-blocking (engine.itree_allreduce) before
    # any is waited, so buckets across sync groups sit in the CCLO-style
    # command queue together — small same-dtype buckets coalesce and the
    # drain overlaps independent buckets' latency (bitwise-identical to
    # the blocking path by the queue's coalescing eligibility rule).
    async_grad_sync: bool = True


ASSIGNED_ARCHS = (
    "internvl2_26b", "mamba2_1p3b", "qwen3_14b", "smollm_360m",
    "qwen3_0p6b", "stablelm_12b", "mixtral_8x7b", "qwen3_moe_30b_a3b",
    "whisper_medium", "hymba_1p5b",
)

# CLI ids (--arch) use dashes/dots per the assignment table.
ARCH_IDS = {
    "internvl2-26b": "internvl2_26b",
    "mamba2-1.3b": "mamba2_1p3b",
    "qwen3-14b": "qwen3_14b",
    "smollm-360m": "smollm_360m",
    "qwen3-0.6b": "qwen3_0p6b",
    "stablelm-12b": "stablelm_12b",
    "mixtral-8x7b": "mixtral_8x7b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "whisper-medium": "whisper_medium",
    "hymba-1.5b": "hymba_1p5b",
}


def get_config(name: str) -> ArchConfig:
    mod_name = ARCH_IDS.get(name, name)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def reduced_config(cfg: ArchConfig, **overrides) -> ArchConfig:
    """Smoke-test scale: same family/topology, tiny dimensions."""
    shrink = dict(
        n_layers=2,
        d_model=64,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_heads else 0,
        head_dim=16 if cfg.n_heads else 0,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        n_experts=min(cfg.n_experts, 4),
        experts_per_token=min(cfg.experts_per_token, 2),
        moe_d_ff=64 if cfg.moe_d_ff else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        ssm_chunk=16,
        sliding_window=min(cfg.sliding_window, 32) if cfg.sliding_window else 0,
        global_attn_layers=tuple(l for l in cfg.global_attn_layers if l < 2),
        encoder_layers=2 if cfg.encoder_layers else 0,
        n_vis_tokens=4 if cfg.n_vis_tokens else 0,
        param_dtype="float32",
        compute_dtype="float32",
    )
    shrink.update(overrides)
    return dataclasses.replace(cfg, **shrink)
