"""Hymba-1.5B — hybrid: parallel attention + SSM heads in every layer;
SWA everywhere except 3 global-attention layers.

[arXiv:2411.13676; hf] 32L, d 1600, 25H/5KV (head 64), ffn 5504,
vocab 32001, ssm_state 16.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab_size=32001,
    ssm_state=16, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
    sliding_window=1024, global_attn_layers=(0, 15, 31),
    rope_theta=1e4,
    source="arXiv:2411.13676 (Hymba)",
)
