"""Mamba2-1.3B — SSD (state-space duality), attention-free.

[arXiv:2405.21060; unverified] d_model 2048, 48 layers, d_state 128,
expand 2 (d_inner 4096), head_dim 64 (64 SSM heads), conv width 4.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_conv=4, ssm_head_dim=64, ssm_chunk=256,
    tie_embeddings=True,
    source="arXiv:2405.21060 (Mamba2/SSD)",
)
