"""SmolLM-360M — llama-arch small model.

[hf:HuggingFaceTB/SmolLM-360M; hf] 32L, d 960, 15H/5KV (head 64),
ffn 2560, vocab 49152, tied embeddings.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, head_dim=64,
    d_ff=2560, vocab_size=49152, tie_embeddings=True, rope_theta=1e4,
    source="hf:HuggingFaceTB/SmolLM-360M",
)
