"""Whisper-medium — encoder-decoder; conv audio frontend is a STUB
(input_specs() provides precomputed frame embeddings).

[arXiv:2212.04356; unverified] 24+24L, d 1024, 16H (MHA: kv=16, head 64),
ffn 4096, vocab 51865.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium", family="audio",
    n_layers=24, encoder_layers=24,
    d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=51865, rope_theta=1e4,
    source="arXiv:2212.04356 (Whisper)",
)
