"""Industrial DLRM from the paper's Table 2: 100 tables, concat vec 3200,
FC stack (2048, 512, 256), 50 GB embeddings.

The use-case config (paper §6); not one of the 40 assigned LM cells.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    n_tables: int = 100
    emb_dim: int = 32            # 3200 / 100 lookups
    rows_per_table: int = 4_000_000   # ~51 GB total at fp32 x 32-dim
    dense_features: int = 0
    fc_dims: tuple = (2048, 512, 256)
    out_dim: int = 1


CONFIG = DLRMConfig()


def reduced() -> DLRMConfig:
    return DLRMConfig(n_tables=8, emb_dim=16, rows_per_table=1000,
                      fc_dims=(64, 32), out_dim=1)
