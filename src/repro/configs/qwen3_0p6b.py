"""Qwen3-0.6B — dense GQA with qk_norm, wide head_dim (128 > d/H).

[hf:Qwen/Qwen3-0.6B; hf] 28L, d 1024, 16H/8KV head_dim 128, ffn 3072,
vocab 151936, tied embeddings.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-0.6b", family="dense",
    n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=3072, vocab_size=151936, qk_norm=True, tie_embeddings=True,
    rope_theta=1e6,
    source="hf:Qwen/Qwen3-0.6B",
)
