"""InternVL2-26B backbone: InternViT-6B (stubbed frontend) + InternLM2-20B.

[arXiv:2404.16821; hf] — transformer backbone only; input_specs() supplies
precomputed patch embeddings for the visual prefix (256 tokens).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=92553,
    rope_theta=1e6, tie_embeddings=False,
    n_vis_tokens=256,
    source="arXiv:2404.16821 (InternVL2) / InternLM2-20B backbone [hf]",
)
