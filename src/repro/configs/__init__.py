from repro.configs.base import (
    ArchConfig, ParallelConfig, ShapeConfig, SHAPES, ARCH_IDS,
    ASSIGNED_ARCHS, get_config, reduced_config,
)

__all__ = [
    "ArchConfig", "ParallelConfig", "ShapeConfig", "SHAPES", "ARCH_IDS",
    "ASSIGNED_ARCHS", "get_config", "reduced_config",
]
