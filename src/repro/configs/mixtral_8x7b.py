"""Mixtral-8x7B — MoE (8 experts, top-2) with sliding-window attention.

[arXiv:2401.04088; hf] 32L, d 4096, 32H/8KV head 128, expert ffn 14336,
vocab 32000, SWA window 4096.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=0, vocab_size=32000,
    n_experts=8, experts_per_token=2, moe_d_ff=14336,
    sliding_window=4096, rope_theta=1e6,
    source="arXiv:2401.04088 (Mixtral)",
)
