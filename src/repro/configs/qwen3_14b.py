"""Qwen3-14B — dense GQA decoder with qk_norm.

[hf:Qwen/Qwen3-8B family; hf] 40L, d 5120, 40H/8KV, head_dim 128,
ffn 17408, vocab 151936.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=17408, vocab_size=151936, qk_norm=True, rope_theta=1e6,
    source="hf:Qwen/Qwen3-14B",
)
