"""Sharded, async, elastically-reshardable checkpointing.

Format (one directory per step):
    step_000123/
      manifest.json     step, mesh shape, per-leaf {path, shape, dtype, spec}
      <leaf-id>.npy     full logical array (assembled from addressable
                        shards; single-process here, but written through
                        the same gather path a multi-host runtime uses)
      COMMIT            written last — a directory without it is garbage
                        (atomic-commit protocol; interrupted saves are
                        ignored by latest_step and GC'd)

Elastic restart: load_checkpoint re-device_puts every leaf with the specs
of the *target* mesh, so a checkpoint from a 512-chip run restores onto any
other mesh shape (tested 8 -> 4 and 4 -> 8 devices).

Async: save_checkpoint(..., blocking=False) snapshots to host in the caller
thread (cheap device->host copies) and writes files on a background thread;
`wait()` joins before the next save or shutdown.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def _leaf_paths(tree):
    flat, _ = jax.tree.flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "_".join(re.sub(r"[^A-Za-z0-9]", "", str(p)) for p in path)
        out.append((name, path, leaf))
    return out


def _spec_to_json(spec: P):
    return [list(e) if isinstance(e, (tuple, list)) else e for e in spec]


def save_checkpoint(directory: str, step: int, tree, specs=None,
                    extra: Optional[dict] = None):
    """Synchronous sharded save with atomic commit."""
    d = os.path.join(directory, f"step_{step:09d}")
    tmp = d + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    spec_flat = None
    if specs is not None:
        spec_flat = {tuple(p): s for p, s in jax.tree.flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, P))[0]}
    for name, path, leaf in _leaf_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, name + ".npy"), arr)
        entry = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
        if spec_flat is not None:
            entry["spec"] = _spec_to_json(spec_flat[tuple(path)])
        manifest["leaves"][name] = entry
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "COMMIT"), "w") as f:
        f.write("ok")
    if os.path.exists(d):
        shutil.rmtree(d)
    os.rename(tmp, d)
    return d


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    best = None
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "COMMIT")):
            best = max(best or -1, int(m.group(1)))
    return best


def load_checkpoint(directory: str, step: int, tree_like, specs=None,
                    mesh=None):
    """Restore into the structure of `tree_like`, resharding onto `mesh`."""
    d = os.path.join(directory, f"step_{step:09d}")
    if not os.path.exists(os.path.join(d, "COMMIT")):
        raise FileNotFoundError(f"no committed checkpoint at {d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    spec_flat = None
    if specs is not None:
        spec_flat = {tuple(p): s for p, s in jax.tree.flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, P))[0]}

    names = {}
    for name, path, leaf in _leaf_paths(tree_like):
        names[name] = (path, leaf)
    out_flat = {}
    for name, entry in manifest["leaves"].items():
        if name not in names:
            raise KeyError(f"checkpoint leaf {name} missing in target tree")
        path, leaf = names[name]
        arr = np.load(os.path.join(d, name + ".npy"))
        if mesh is not None and spec_flat is not None:
            arr = jax.device_put(
                arr, NamedSharding(mesh, spec_flat[tuple(path)]))
        out_flat[tuple(path)] = arr
    flat, treedef = jax.tree.flatten_with_path(tree_like)
    ordered = [out_flat[tuple(p)] for p, _ in flat]
    return jax.tree.unflatten(treedef, ordered), manifest


class CheckpointManager:
    """Async keep-K manager with atomic commits and exact resume."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, tree, specs=None, extra=None,
             blocking: bool = False):
        self.wait()
        # snapshot to host in-caller (device buffers may be donated later)
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, specs,
                                extra)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if blocking:
            work()
            if self._error:
                raise self._error
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def restore_latest(self, tree_like, specs=None, mesh=None):
        step = latest_step(self.directory)
        if step is None:
            return None
        tree, manifest = load_checkpoint(self.directory, step, tree_like,
                                         specs, mesh)
        return step, tree, manifest

    def _gc(self):
        steps = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                committed = os.path.exists(
                    os.path.join(self.directory, name, "COMMIT"))
                if not committed and not name.endswith(".tmp"):
                    shutil.rmtree(os.path.join(self.directory, name),
                                  ignore_errors=True)
                    continue
                steps.append(int(m.group(1)))
        for s in sorted(steps)[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"),
                          ignore_errors=True)
