"""Aggregate results/dryrun JSONs into the EXPERIMENTS.md roofline table."""
import glob
import json
import os


def load_results(results_dir="results/dryrun", variant="base", mesh="single"):
    rows = []
    for path in sorted(glob.glob(os.path.join(
            results_dir, f"*_{mesh}_{variant}.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def fmt_table(rows, md=True):
    out = []
    hdr = ("arch", "shape", "status", "fits", "t_comp(ms)", "t_memfloor(ms)",
           "t_coll(ms)", "dominant", "MFU", "model/HLO")
    out.append(" | ".join(hdr) if md else ",".join(hdr))
    if md:
        out.append(" | ".join(["---"] * len(hdr)))
    for r in rows:
        if r.get("status") != "OK":
            out.append(" | ".join([r.get("arch", "?"), r.get("shape", "?"),
                                   r.get("status", "?")[:40]] + [""] * 7))
            continue
        t = r["roofline"]
        floor = t.get("t_memory_floor_s", t["t_memory_s"])
        terms = {"compute": t["t_compute_s"], "memory": floor,
                 "collective": t["t_collective_s"]}
        dominant = max(terms, key=terms.get)
        step = max(terms.values())
        peak = 197e12
        mfu = (r["model_flops"] / (r["chips"] * peak * step)) if step else 0
        vals = [
            r["arch"], r["shape"], "OK", str(r["fits_hbm"]),
            f"{t['t_compute_s']*1e3:.2f}", f"{floor*1e3:.2f}",
            f"{t['t_collective_s']*1e3:.2f}", dominant,
            f"{mfu:.3f}",
            f"{r.get('model_flops_ratio') or 0:.2f}",
        ]
        out.append(" | ".join(vals) if md else ",".join(vals))
    return "\n".join(out)


def main():
    for mesh in ("single", "multi"):
        rows = load_results(mesh=mesh)
        if not rows:
            continue
        print(f"\n== roofline table ({mesh}-pod) ==")
        print(fmt_table(rows))


if __name__ == "__main__":
    main()
