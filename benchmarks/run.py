"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Wall-clock columns are host-CPU
relative numbers; `derived` carries the alpha-beta model for the paper's
cluster and the TPU target (quoted in EXPERIMENTS.md).
"""
from benchmarks.common import header


def main() -> None:
    from benchmarks import figures
    header()
    figures.fig07_sendrecv()
    figures.fig08_invocation()
    figures.fig10_collectives(h2h=False)
    figures.fig10_collectives(h2h=True)
    figures.fig12_scaling()
    figures.fig13_backend_compare()
    figures.fig16_vecmat()
    figures.fig17_dlrm()
    figures.table3_resources()


if __name__ == "__main__":
    main()
