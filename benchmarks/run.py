"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV and writes the same data (plus the
structured segment sweep) to a machine-readable JSON file so the perf
trajectory is tracked across PRs. See benchmarks/README.md.
"""
import argparse
import json

from benchmarks.common import RESULTS, header, reset_results

DEFAULT_JSON = "BENCH_collectives.json"


def _parse_segments(text: str):
    return tuple(int(t) for t in text.split(",") if t)


def _selector_default_segments():
    from repro.core import Selector
    return Selector.DEFAULT_SEGMENT_CANDIDATES


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.run",
        description="Run the paper-figure benchmarks and the segment sweep.")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="where to write the machine-readable results "
                         f"(default: {DEFAULT_JSON} for full runs; with "
                         "--only no file is written unless --json is "
                         "given explicitly; empty string disables)")
    ap.add_argument("--only", default=None, metavar="NAME",
                    help="run a single benchmark (e.g. fig10_collectives, "
                         "seg_sweep) instead of the full set")
    ap.add_argument("--quick", action="store_true",
                    help="run only the deterministic model benchmarks "
                         "(fig12_scaling + seg_sweep + queue_sweep + "
                         "fault_sweep + hier_sweep + contention_sweep) — "
                         "the CI bench-gate mode; still writes the JSON "
                         "results file")
    default_segments = ",".join(
        str(k) for k in _selector_default_segments())
    ap.add_argument("--segments", default=default_segments,
                    metavar="K1,K2,...",
                    help="segment counts the sweep prices "
                         f"(default: the selector's ladder, "
                         f"{default_segments})")
    ap.add_argument("--sweep-ranks", type=int, default=8,
                    help="communicator size for the segment sweep")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record the run under a telemetry Tracer and "
                         "write the Chrome trace-event JSON here "
                         "(open in Perfetto, or summarize with "
                         "scripts/trace_report.py). The tracer is "
                         "read-only: priced outputs are bitwise "
                         "identical with or without it.")
    args = ap.parse_args(argv)
    if args.only and args.quick:
        ap.error("--only and --quick are mutually exclusive")
    if args.json is None:
        # a partial run must not clobber the full tracked results file;
        # --quick is the CI gate and always writes (check_bench reads it)
        args.json = "" if args.only else DEFAULT_JSON

    from benchmarks import figures
    reset_results()
    header()

    try:
        sweep_counts = _parse_segments(args.segments)
    except ValueError:
        ap.error(f"--segments must be comma-separated integers, "
                 f"got {args.segments!r}")
    if not sweep_counts:
        ap.error("--segments needs at least one count, e.g. --segments 1,4")
    if any(k < 1 for k in sweep_counts):
        ap.error(f"--segments counts must be >= 1, got {args.segments!r}")

    def seg_sweep():
        figures.seg_sweep(segment_counts=sweep_counts,
                          nranks=args.sweep_ranks)

    benches = {
        "fig07_sendrecv": figures.fig07_sendrecv,
        "fig08_invocation": figures.fig08_invocation,
        "fig10_collectives": lambda: (figures.fig10_collectives(h2h=False),
                                      figures.fig10_collectives(h2h=True)),
        "fig12_scaling": figures.fig12_scaling,
        "fig13_backend_compare": figures.fig13_backend_compare,
        "seg_sweep": seg_sweep,
        "queue_sweep": figures.queue_sweep,
        "fault_sweep": figures.fault_sweep,
        "hier_sweep": figures.hier_sweep,
        "contention_sweep": figures.contention_sweep,
        "fig16_vecmat": figures.fig16_vecmat,
        "fig17_dlrm": figures.fig17_dlrm,
        "table3_resources": figures.table3_resources,
    }
    if args.only is not None:
        if args.only not in benches:
            ap.error(f"unknown benchmark {args.only!r}; "
                     f"have {sorted(benches)}")
        benches = {args.only: benches[args.only]}
    elif args.quick:
        # the deterministic (pure cost-model) subset CI gates on
        benches = {"fig12_scaling": benches["fig12_scaling"],
                   "seg_sweep": benches["seg_sweep"],
                   "queue_sweep": benches["queue_sweep"],
                   "fault_sweep": benches["fault_sweep"],
                   "hier_sweep": benches["hier_sweep"],
                   "contention_sweep": benches["contention_sweep"]}
    if args.trace:
        from repro.core import telemetry
        with telemetry.use(telemetry.Tracer()) as tracer:
            for fn in benches.values():
                fn()
        trace_doc = tracer.to_chrome_trace()
        with open(args.trace, "w") as f:
            json.dump(trace_doc, f)
        print(f"# wrote {args.trace}: "
              f"{len(trace_doc['traceEvents'])} trace events")
    else:
        for fn in benches.values():
            fn()

    results = {
        "meta": _meta(),
        "rows": list(RESULTS["rows"]),
        "segment_sweep": list(RESULTS["segment_sweep"]),
        "queue_sweep": list(RESULTS["queue_sweep"]),
        "fault_sweep": list(RESULTS["fault_sweep"]),
        "hier_sweep": list(RESULTS["hier_sweep"]),
        "contention_sweep": list(RESULTS["contention_sweep"]),
    }
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1)
        print(f"# wrote {args.json}: {len(results['rows'])} rows, "
              f"{len(results['segment_sweep'])} sweep points, "
              f"{len(results['queue_sweep'])} queue points, "
              f"{len(results['fault_sweep'])} fault points, "
              f"{len(results['hier_sweep'])} hier points, "
              f"{len(results['contention_sweep'])} contention points")
    return results


def _meta() -> dict:
    import jax
    return {
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
    }


if __name__ == "__main__":
    main()
