"""Benchmark harness utilities.

CPU wall-clock numbers are meaningful only RELATIVELY (algorithm A vs B on
the same host simulator); every figure also emits `derived` columns from
the alpha-beta cost model for the paper's 100 Gb/s cluster and the TPU v5e
target, which is what EXPERIMENTS.md quotes.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402


def timeit(fn, *args, warmup=2, iters=10):
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


# Machine-readable mirror of everything row() prints, plus structured
# records benchmarks attach directly (segment sweeps, queue sweeps).
# run.py serializes this into BENCH_collectives.json so the perf
# trajectory is diffable across PRs.
RESULTS = {"rows": [], "segment_sweep": [], "queue_sweep": [],
           "fault_sweep": [], "hier_sweep": [], "contention_sweep": []}


def row(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.2f},{derived}")
    RESULTS["rows"].append(
        {"name": name, "us_per_call": round(float(us), 3),
         "derived": derived})


def record_sweep(entry: dict):
    """Attach one structured segment-sweep record (see figures.seg_sweep)."""
    RESULTS["segment_sweep"].append(entry)


def record_queue(entry: dict):
    """Attach one structured queue-sweep record (see figures.queue_sweep)."""
    RESULTS["queue_sweep"].append(entry)


def record_fault(entry: dict):
    """Attach one structured fault-sweep record (see figures.fault_sweep)."""
    RESULTS["fault_sweep"].append(entry)


def record_hier(entry: dict):
    """Attach one structured hier-sweep record (see figures.hier_sweep)."""
    RESULTS["hier_sweep"].append(entry)


def record_contention(entry: dict):
    """Attach one structured contention-sweep record (see
    figures.contention_sweep)."""
    RESULTS["contention_sweep"].append(entry)


def reset_results():
    RESULTS["rows"].clear()
    RESULTS["segment_sweep"].clear()
    RESULTS["queue_sweep"].clear()
    RESULTS["fault_sweep"].clear()
    RESULTS["hier_sweep"].clear()
    RESULTS["contention_sweep"].clear()


def header():
    print("name,us_per_call,derived")
