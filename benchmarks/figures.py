"""One benchmark per paper table/figure (see DESIGN.md section 6)."""
import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from benchmarks.common import (
    record_contention, record_fault, record_hier, record_queue,
    record_sweep, row, timeit,
)
from repro.core import (
    CollectiveEngine, Communicator, MeshMakespan, PricingEnv, Selector,
)
from repro.core.hw_spec import ACCL_CLUSTER, TPU_V5E
from repro.core.topology import make_mesh
from repro.core import algorithms as A


def _mesh8():
    return make_mesh((8,), ("x",))


def _engine(backend="microcode"):
    return CollectiveEngine(_mesh8(), backend=backend)


def _sharded(fn, mesh, in_specs, out_specs):
    return jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False))


# -- Fig 7: send/recv throughput ---------------------------------------------

def fig07_sendrecv():
    mesh = _mesh8()
    eng = CollectiveEngine(mesh)
    for log2 in (10, 14, 18, 22, 26):
        nbytes = 1 << log2
        x = jnp.zeros((nbytes // 4,), jnp.float32)
        g = _sharded(lambda v: eng.send_recv(v, "x"), mesh, P(None), P(None))
        us = timeit(g, x)
        # derived: modeled time on the paper cluster and on TPU ICI
        t_accl = nbytes / ACCL_CLUSTER.ici_link_bw + ACCL_CLUSTER.ici_hop_latency
        t_tpu = nbytes / TPU_V5E.ici_link_bw + TPU_V5E.ici_hop_latency
        gbps_accl = nbytes * 8 / t_accl / 1e9
        row(f"fig07/sendrecv/{nbytes>>10}KB", us,
            f"accl_model={gbps_accl:.1f}Gbps tpu_model={nbytes/t_tpu/1e9:.1f}GBps")


# -- Fig 8: invocation latency ------------------------------------------------

def fig08_invocation():
    mesh = _mesh8()
    eng = CollectiveEngine(mesh)
    nop = _sharded(lambda v: v + eng.nop(), mesh, P(None), P(None))
    x = jnp.zeros((8,), jnp.int32)
    us_host = timeit(nop, x)            # host dispatch of a cached program
    row("fig08/invocation/host_cached", us_host,
        "coyote-driver analogue: cached jit dispatch")

    # F2F analogue: N nops inside one graph — per-op cost
    def many(v):
        for _ in range(100):
            v = v + eng.nop()
        return v
    g = _sharded(many, mesh, P(None), P(None))
    us_g = timeit(g, x) / 100
    row("fig08/invocation/in_graph", us_g,
        "F2F analogue: kernel-to-engine, no host roundtrip")

    # XRT analogue: dispatch including retrace (uncached path)
    import time as _t
    def retrace():
        f = jax.jit(lambda v: v + 1)
        t0 = _t.perf_counter()
        f(x).block_until_ready()
        return (_t.perf_counter() - t0) * 1e6
    row("fig08/invocation/host_retrace", retrace(),
        "XRT analogue: heavyweight dispatch path")


# -- Figs 10/11: collective latency ------------------------------------------

def fig10_collectives(h2h: bool = False):
    mesh = _mesh8()
    tag = "fig11/h2h" if h2h else "fig10/f2f"
    comm = Communicator(axis="x", size=8)
    sel = Selector()
    for coll in ("bcast", "reduce", "gather", "alltoall", "allreduce"):
        for log2 in (12, 17, 22):
            nbytes = 1 << log2
            elems = nbytes // 4
            per = elems // 8 * 8
            for backend in ("microcode", "native"):
                eng = CollectiveEngine(mesh, backend=backend)
                def fn(v, e=eng, c=coll):
                    y = getattr(e, c)(v, "x") if c != "alltoall" \
                        else e.alltoall(v.reshape(8, -1)).reshape(-1)
                    return y.reshape(-1)[:1]
                if coll == "alltoall":
                    def fn(v, e=eng):  # noqa: F811
                        return e.alltoall(v.reshape(8, -1), "x").reshape(-1)[:1]
                g = _sharded(fn, mesh, P(None), P(None))
                host_np = np.zeros((per,), np.float32)
                if h2h:  # include host->device staging, like the paper's H2H
                    def call(arr=host_np, g=g):
                        return g(jnp.asarray(arr))
                    us = timeit(call)
                else:
                    x = jnp.zeros((per,), jnp.float32)
                    us = timeit(g, x)
                choice = sel.choose(coll if coll != "allreduce"
                                    else "allreduce", nbytes, comm)
                row(f"{tag}/{coll}/{nbytes>>10}KB/{backend}", us,
                    f"selected={choice.algorithm}/{choice.protocol} "
                    f"segments={choice.segments} "
                    f"compressed={choice.compressed} "
                    f"tpu_model={choice.predicted_s*1e6:.1f}us")


# -- Fig 12: algorithm selection & scalability --------------------------------

def fig12_scaling():
    sel = Selector()
    for nbytes, label in ((8 << 10, "8KB"), (128 << 10, "128KB")):
        for n in (2, 4, 8, 16):
            comm = Communicator(axis="x", size=n)
            c = sel.choose("reduce", nbytes, comm)
            preds = {}
            for algo in ("ring", "all_to_one", "binomial_tree"):
                try:
                    from repro.core.engine import _gen_schedule
                    sched = _gen_schedule("reduce", algo, comm)
                    preds[algo] = sched.compile().cost(nbytes, comm) * 1e6
                except ValueError:
                    pass
            row(f"fig12/reduce/{label}/{n}ranks", preds[c.algorithm],
                f"selected={c.algorithm} " +
                " ".join(f"{k}={v:.1f}us" for k, v in preds.items()))


# -- Segment sweep: pipelined protocol (paper §4.4.3 / Fig 10 knob) -----------

#: named schedules swept IN ADDITION to the selector's auto picks — the
#: tree / masked / recursive-doubling algorithms that became segmentable
#: when the data plane unified behind the micro-op executor. Every one of
#: these lowers through the same SEG_LOOP pipeline as the rings now.
SEG_SWEEP_NAMED = (
    ("allreduce", "halving_doubling"),      # recursive halving + doubling
    ("allreduce", "recursive_doubling"),    # hypercube, SEL_ALL steps
    ("reduce_scatter", "recursive_halving"),
    ("reduce", "binomial_tree"),            # tree with masked receivers
    ("reduce", "ring"),                     # relay='received' eager ring
    ("bcast", "binomial_tree"),
    ("alltoall", "bruck"),                  # SEL_MASK gather/scatter segs
)


def seg_sweep(segment_counts=None, nranks: int = 8,
              sizes=(1 << 16, 1 << 20, 1 << 22, 1 << 24, 1 << 26)):
    """Alpha-beta time vs wire segment count, per schedule and size.

    Pure model (no device timing): this is the paper's Rx-buffer-size
    latency knob (arXiv 2403.18374 shows it dominating collective latency
    at scale). Sweeps the selector's auto pick for the big three
    collectives plus SEG_SWEEP_NAMED — the tree/masked/recursive
    schedules the micro-op executor made segmentable. Every point is
    priced by `Program.cost` on the COMPILED program (the same artifact
    the engine executes, stream/chain fusion included; `streamed` marks
    programs that cross-step pipeline). Under the split pricing model,
    segmentation pays ONLY where the program streams: streamed curves
    must strictly dominate their 1-segment baseline for messages
    >= 1 MiB, while SEG_LOOP-only curves are serialized and their best
    count is k=1 — both facts are gated by tests/test_benchmarks.py.
    Emits one printed row per (schedule, size) with the best segment
    count, and one structured record per (schedule, size, segments) into
    BENCH_collectives.json — the curve `scripts/check_bench.py` gates CI
    against.
    """
    from repro.core.engine import _gen_schedule
    from repro.core.program import Stream, StreamChain
    from repro.core.selector import ALGO_PROTOCOLS

    if segment_counts is None:
        # price the ladder the selector actually picks from
        segment_counts = Selector.DEFAULT_SEGMENT_CANDIDATES
    # the 1-segment baseline is always priced: dominance is relative to it
    segment_counts = sorted(set(int(k) for k in segment_counts) | {1})
    comm = Communicator(axis="x", size=nranks)
    sel = Selector()

    items = [(coll, None) for coll in
             ("allreduce", "reduce_scatter", "allgather")]
    items += [(c, a) for (c, a) in SEG_SWEEP_NAMED
              if comm.is_pow2 or a not in
              ("halving_doubling", "recursive_doubling",
               "recursive_halving", "bruck")]

    emitted = set()  # (collective, algorithm, msg_bytes) curves recorded
    for coll, named_algo in items:
        for nbytes in sizes:
            if named_algo is None:
                choice = sel.choose(coll, nbytes, comm)
                sched = choice.schedule
                algo, proto = choice.algorithm, choice.protocol
                chosen_k = choice.segments
                label = coll
            else:
                if (coll, named_algo, int(nbytes)) in emitted:
                    continue  # the auto pick already recorded this curve
                sched = _gen_schedule(coll, named_algo, comm)
                algo = named_algo
                proto = ALGO_PROTOCOLS.get((coll, algo),
                                           ("rendezvous",))[0]
                chosen_k = None
                label = f"{coll}.{algo}"
            emitted.add((coll, algo, int(nbytes)))
            # whether the selector would ever auto-segment this schedule
            # at this size (copy-only schedules and sub-floor messages
            # never are) — single source of truth: admissible_segments
            auto_ok = sel.admissible_segments(sched, nbytes, comm) != (1,)
            copy_only = all(s.op == "copy" for s in sched.steps)
            why_not = "copy-only" if copy_only else "below-floor"
            times = {}
            for k in segment_counts:
                prog = sched.compile(segments=k)
                t = prog.cost(nbytes, comm)
                times[k] = t
                record_sweep({
                    "collective": coll,
                    "algorithm": algo,
                    "protocol": proto,
                    "auto": named_algo is None,
                    "nranks": nranks,
                    "msg_bytes": int(nbytes),
                    "segments": int(k),
                    "predicted_s": t,
                    "selected": k == chosen_k,
                    "auto_segmentable": auto_ok,
                    "streamed": any(isinstance(op, (Stream, StreamChain))
                                    for op in prog.ops),
                })
            best_k = min(times, key=times.get)
            dominated = times[best_k] < times[1]
            row(f"segsweep/{label}/{nbytes>>10}KB/{nranks}ranks",
                times[best_k] * 1e6,
                f"algo={algo} best_segments={best_k} "
                f"t1={times[1]*1e6:.1f}us "
                f"speedup={times[1]/times[best_k]:.2f}x "
                f"dominates={dominated}"
                + ("" if auto_ok else f" auto=1seg({why_not})"))


# -- Queue sweep: the offload request queue's makespan model ------------------

def queue_sweep(request_counts=(1, 2, 4, 8), nranks: int = 8,
                sizes=(1 << 12, 1 << 16, 1 << 20, 1 << 24)):
    """Queue makespan vs serial-blocking cost, per request count and size.

    Pure model (no device timing): a queue of `m` INDEPENDENT same-axis
    allreduces is issued into a `Sequencer` and priced two ways —
    `Sequencer.makespan` (the queue-level pipelining model: wire
    occupancy serializes across the drain, queued requests' per-hop
    alpha hides behind the request in flight, dependency chains — none
    here — serialize in full) and `Sequencer.serial_cost` (the sum of
    blocking `Program.cost`s, what m back-to-back blocking calls would
    price). Small sizes additionally coalesce into ONE bucketed program
    (the paper's offload win for many tiny CPU-side calls — `coalesced`
    marks those points). Every point lands in BENCH_collectives.json's
    `queue_sweep` section, which `scripts/check_bench.py` gates next to
    the segment sweep.
    """
    from repro.core.sequencer import Sequencer

    mesh = make_mesh((nranks,), ("x",))
    eng = CollectiveEngine(mesh)
    comm = Communicator(axis="x", size=nranks)
    for nbytes in sizes:
        for m in request_counts:
            seq = Sequencer(eng)
            for _ in range(m):
                # distinct buffers: the requests are independent (no
                # conflict edges), the overlap-credit case
                seq.issue("allreduce",
                          np.zeros((nbytes // 4,), np.float32), "x")
            plan = seq.plan("x")
            makespan = seq.makespan("x", env=PricingEnv(comm=comm))
            serial = seq.serial_cost("x", comm=comm)
            coalesced = any(it.coalesced for it in plan)
            record_queue({
                "collective": "allreduce",
                "nranks": nranks,
                "msg_bytes": int(nbytes),
                "requests": int(m),
                "makespan_s": makespan,
                "serial_s": serial,
                "coalesced": coalesced,
            })
            row(f"queuesweep/allreduce/{m}req/{nbytes>>10}KB/"
                f"{nranks}ranks", makespan * 1e6,
                f"serial={serial*1e6:.1f}us "
                f"speedup={serial/makespan:.2f}x "
                f"items={len(plan)} coalesced={coalesced}")


# -- Fault sweep: makespan vs drop rate per reliability tier ------------------

def fault_sweep(drop_rates=(0.0, 0.01, 0.05, 0.2), nranks: int = 8,
                sizes=(1 << 16, 1 << 22), tiers=("tcp-like", "rdma-like")):
    """Retransmission-priced queue makespan vs segment drop rate.

    Pure model (no device timing, no randomness): the same queue of four
    independent allreduces is priced under each reliability tier's
    truncated-geometric retransmission model — expected transmissions
    inflate both halves of the alpha-beta cost and each expected retry
    adds the tier's expected exponential backoff per wire crossing
    (Program.cost with tier/drop_prob). `surcharge` is the ratio to the
    fault-free makespan; drop_rate 0.0 must price identical to the base
    model, which `scripts/check_bench.py` gates next to the other sweeps.
    """
    from repro.core.faults import TIERS
    from repro.core.sequencer import Sequencer

    mesh = make_mesh((nranks,), ("x",))
    eng = CollectiveEngine(mesh)
    comm = Communicator(axis="x", size=nranks)
    for nbytes in sizes:
        seq = Sequencer(eng)
        for _ in range(4):
            seq.issue("allreduce", np.zeros((nbytes // 4,), np.float32),
                      "x")
        base = seq.makespan("x", env=PricingEnv(comm=comm))
        for tier_name in tiers:
            tier = TIERS[tier_name]
            for p in drop_rates:
                makespan = seq.makespan("x", env=PricingEnv(
                    comm=comm, tier=tier, drop_prob=p))
                record_fault({
                    "collective": "allreduce",
                    "nranks": nranks,
                    "msg_bytes": int(nbytes),
                    "tier": tier_name,
                    "drop_rate": float(p),
                    "makespan_s": makespan,
                    "surcharge": makespan / base,
                })
                row(f"faultsweep/allreduce/{tier_name}/p{p:g}/"
                    f"{nbytes>>10}KB/{nranks}ranks", makespan * 1e6,
                    f"E={tier.expected_transmissions(p):.3f} "
                    f"surcharge={makespan/base:.3f}x "
                    f"retries<={tier.max_retries}")
        seq.clear()


# -- Hier sweep: two-level cross-fabric allreduce vs flat ---------------------

def hier_sweep(pod_sizes=(2, 4), nranks: int = 16,
               sizes=(1 << 16, 1 << 18, 1 << 20, 1 << 22, 1 << 24,
                      1 << 26)):
    """Modeled flat vs hierarchical allreduce across a DCN pod boundary.

    Pure model (no device timing): for each pod count, an allreduce over
    a (pod x intra-pod) product communicator is priced two ways — the
    best FLAT algorithm over the bottleneck view (every link rides DCN)
    and the best two-level `hierarchical:<intra>+<inter>` composition
    (reduce-scatter in pod, inter-pod allreduce of the 1/ici_size shard,
    allgather in pod). Each side sweeps its own admissible segment
    ladder, exactly as `Selector._choose_product` prices the head-to-head
    pick. `dcn_ratio` is the priced-DCN-wire-byte quotient hier/flat:
    for matched ring families it is exactly 1/ici_size (the headline
    claim, asserted bitwise in tests/test_hierarchical.py); the recorded
    ratio uses each side's own best algorithm, so it reports what the
    selector actually ships. Every point lands in the `hier_sweep`
    section of BENCH_collectives.json, which `scripts/check_bench.py`
    gates — the modeled hierarchical speedup is pinned by the committed
    baseline, not just eyeballed.
    """
    from repro.core import hierarchical as H

    for pod in pod_sizes:
        comm = Communicator(axis="pod", size=nranks,
                            is_dcn=True).factor(pod)
        sel = Selector()
        for nbytes in sizes:
            # best flat candidate over the bottleneck (all-DCN) view;
            # flat programs price bitwise-identically on the product
            flat_c = sel.choose("allreduce", nbytes, comm.flat)
            flat_dcn = flat_c.program.fabric_wire_bytes(
                nbytes, comm.flat)["dcn"]
            # best hierarchical composition (rendezvous-only, inner-
            # fabric segment floors — mirrors _choose_product)
            hier_best = None
            for intra in H.INTRA_ALGOS:
                for inter in H.inter_candidates("allreduce",
                                                comm.outer.size):
                    sched = H.hierarchical_schedule(
                        "allreduce", comm, intra=intra, inter=inter)
                    segs = sel.fit_candidate_segments(
                        sched, nbytes,
                        sel.admissible_segments(sched, nbytes,
                                                comm.inner))
                    for k in segs:
                        prog = sched.with_segments(k).compile()
                        t = sel.price_program(prog, "rendezvous",
                                              nbytes, comm)
                        if t is not None and (hier_best is None
                                              or t < hier_best[0]):
                            hier_best = (t, sched.name, k, prog)
            hier_s, hier_algo, hier_k, hier_prog = hier_best
            hier_dcn = hier_prog.fabric_wire_bytes(nbytes, comm)["dcn"]
            record_hier({
                "collective": "allreduce",
                "nranks": nranks,
                "pod_size": int(pod),
                "msg_bytes": int(nbytes),
                "flat_s": flat_c.predicted_s,
                "flat_algorithm": flat_c.algorithm,
                "hier_s": hier_s,
                "hier_algorithm": hier_algo,
                "hier_segments": int(hier_k),
                "speedup": flat_c.predicted_s / hier_s,
                "dcn_ratio": hier_dcn / flat_dcn,
            })
            row(f"hiersweep/allreduce/pod{pod}/{nbytes>>10}KB/"
                f"{nranks}ranks", hier_s * 1e6,
                f"hier={hier_algo}(k={hier_k}) "
                f"flat={flat_c.algorithm}={flat_c.predicted_s*1e6:.1f}us "
                f"speedup={flat_c.predicted_s/hier_s:.2f}x "
                f"dcn_ratio={hier_dcn/flat_dcn:.3f}")


# -- Contention sweep: mesh-level makespan across concurrent queues ----------

def contention_sweep(queue_counts=(1, 2, 4),
                     sizes=(1 << 16, 1 << 20, 1 << 24),
                     requests_per_queue: int = 8):
    """Mesh-level contention-aware makespan vs per-queue optimism.

    Pure model (no device timing): `q` concurrent `Sequencer` queues of
    independent allreduces are composed by `MeshMakespan` over the
    physical links (`topology.FabricOccupancy`). Two modes:

      * `shared` — every queue runs on the SAME ICI axis, so their wire
        seconds serialize on one link: the mesh makespan approaches the
        serial sum (two saturating queues price ~2x one queue, not
        ~1x — the honest shared-fabric accounting per-queue pricing
        cannot see).
      * `disjoint` — queues alternate between an ICI axis and the DCN
        pod axis; the busiest link bounds, so the mesh makespan tracks
        the SLOWER queue (~1x max, not the sum).

    `mesh_s` (the composition) and `max_queue_s` (the largest isolated
    per-queue makespan — the old model's answer) both land in the
    `contention_sweep` section of BENCH_collectives.json, gated by
    `scripts/check_bench.py`.
    """
    from repro.core.sequencer import Sequencer

    mesh = make_mesh((2, 4), ("pod", "data"))
    eng = CollectiveEngine(mesh)
    for nbytes in sizes:
        for q in queue_counts:
            for mode in ("shared", "disjoint"):
                axes = ["data" if mode == "shared" else
                        ("data", "pod")[i % 2] for i in range(q)]
                mm = MeshMakespan()
                seqs = []
                per_queue = []
                for axis in axes:
                    seq = Sequencer(eng)
                    for _ in range(requests_per_queue):
                        seq.issue("allreduce",
                                  np.zeros((nbytes // 4,), np.float32),
                                  axis)
                    seqs.append((seq, axis))
                    per_queue.append(seq.makespan(axis))
                    mm.add(seq, axis)
                mesh_s = mm.total()
                max_queue = max(per_queue)
                for seq, _axis in seqs:
                    seq.clear()
                record_contention({
                    "collective": "allreduce",
                    "nranks": int(np.prod(list(mesh.shape.values()))),
                    "queues": int(q),
                    "mode": mode,
                    "msg_bytes": int(nbytes),
                    "requests": int(requests_per_queue),
                    "mesh_s": mesh_s,
                    "max_queue_s": max_queue,
                    "ratio": mesh_s / max_queue,
                })
                row(f"contention/allreduce/{q}q/{mode}/{nbytes>>10}KB",
                    mesh_s * 1e6,
                    f"max_queue={max_queue*1e6:.1f}us "
                    f"ratio={mesh_s/max_queue:.2f}x")


# -- Fig 13: engine vs baseline (ACCL+ vs ACCL vs MPI analogue) ---------------

def fig13_backend_compare():
    mesh = _mesh8()
    for log2 in (12, 17, 22):
        per = (1 << log2) // 4 // 8 * 8
        x = jnp.zeros((per,), jnp.float32)
        results = {}
        for name, eng, algo in (
                ("cclo_microcode", CollectiveEngine(mesh), "ring"),
                ("uc_serialized", CollectiveEngine(mesh), "one_to_all_like"),
                ("sw_mpi_native", CollectiveEngine(mesh, backend="native"),
                 "auto")):
            if algo == "one_to_all_like":
                # ACCL-analogue: control-plane-serialized reduce (relay ring,
                # unchunked, n-1 full-buffer hops)
                g = _sharded(lambda v, e=eng: e.reduce(
                    v, "x", algorithm="ring").reshape(-1)[:1],
                    mesh, P(None), P(None))
            else:
                g = _sharded(lambda v, e=eng, a=algo: e.allreduce(
                    v, "x", algorithm=a).reshape(-1)[:1],
                    mesh, P(None), P(None))
            results[name] = timeit(g, x)
        base = results["sw_mpi_native"]
        row(f"fig13/allreduce/{1<<(log2-10)}KB",
            results["cclo_microcode"],
            f"vs_native={base:.1f}us vs_uc_serial={results['uc_serialized']:.1f}us")


# -- Fig 16: distributed vector-matrix multiply -------------------------------

def fig16_vecmat():
    mesh = _mesh8()
    eng = CollectiveEngine(mesh)
    rng = np.random.default_rng(0)
    for size in (1024, 4096):
        w = jnp.asarray(rng.normal(size=(size, size)), jnp.float32)
        x = jnp.asarray(rng.normal(size=(size,)), jnp.float32)
        single = jax.jit(lambda a, b: a @ b)
        us_single = timeit(single, x, w)

        def dist(xs, ws):
            part = xs @ ws                      # (size,) partial
            return eng.reduce(part, "x", algorithm="binomial_tree")
        g = _sharded(dist, mesh, (P("x"), P("x", None)), P(None))
        us_dist = timeit(g, x, w)
        # derived: the paper-cluster model — compute splits 8x, reduction
        # costs one binomial tree of a (size,) fp32 vector. (Virtual CPU
        # devices share one core, so the measured column cannot show real
        # speedup; the model column is what EXPERIMENTS.md quotes.)
        cpu_flops = 50e9
        t_single = 2 * size * size / cpu_flops
        accl_comm = Communicator(axis="x", size=8, hw=ACCL_CLUSTER)
        sched = A.binomial_tree_reduce(accl_comm)
        t_red = sched.compile().cost(size * 4, accl_comm)
        model_speedup = t_single / (t_single / 8 + t_red)
        row(f"fig16/vecmat/{size}", us_dist,
            f"single={us_single:.1f}us measured={us_single/us_dist:.2f}x "
            f"model_8rank={model_speedup:.2f}x")


# -- Fig 17: DLRM latency / throughput ----------------------------------------

def fig17_dlrm():
    from repro.configs.dlrm import reduced
    from repro.configs.base import ParallelConfig
    from repro.models import dlrm as dlrm_mod
    from repro.models.common import Builder
    from repro.parallel.ops import ParCtx
    cfg = reduced()
    mesh = make_mesh((1, 1, 8), ("pod", "data", "model"))
    eng = CollectiveEngine(mesh)
    ctx = ParCtx(engine=eng, pcfg=ParallelConfig(), mesh=mesh)
    b = Builder("init", key=jax.random.PRNGKey(0), dtype=jnp.float32)
    params = dlrm_mod.dlrm_params(b, cfg, 8)
    specs = dlrm_mod.dlrm_specs(cfg, 8)
    rng = np.random.default_rng(0)
    for batch in (1, 64):
        idx = jnp.asarray(rng.integers(0, cfg.rows_per_table,
                                       (batch, cfg.n_tables)), jnp.int32)
        g = _sharded(lambda p, i: dlrm_mod.dlrm_forward(p, i, ctx),
                     mesh, (specs, P(None, None)), P(None, None))
        us = timeit(g, params, idx)
        ref = jax.jit(lambda p, i: dlrm_mod.dlrm_reference(p, i))
        us_ref = timeit(ref, params, idx)
        row(f"fig17/dlrm/b{batch}", us,
            f"single_node={us_ref:.1f}us tput={batch/us*1e6:.0f}qps")


# -- Table 3: resource utilization analogue -----------------------------------

def table3_resources():
    from repro.configs import get_config
    from repro.kernels import matmul as mm
    from repro.kernels import fused_reduce as fr
    hw = TPU_V5E
    # engine component budgets (VMEM working sets of the data plane)
    mm_ws = (mm.DEFAULT_BM * mm.DEFAULT_BK * 2
             + mm.DEFAULT_BK * mm.DEFAULT_BN * 2
             + mm.DEFAULT_BM * mm.DEFAULT_BN * 4)
    fr_ws = 2 * fr.DEFAULT_BLOCK_ROWS * fr.LANES * 4
    row("table3/kernel_vmem/matmul_tile", 0,
        f"{mm_ws/2**20:.1f}MiB of {hw.vmem_bytes/2**20:.0f}MiB VMEM "
        f"({100*mm_ws/hw.vmem_bytes:.1f}%)")
    row("table3/kernel_vmem/fused_reduce", 0,
        f"{fr_ws/2**20:.2f}MiB ({100*fr_ws/hw.vmem_bytes:.2f}%)")
    for arch in ("qwen3-14b", "mixtral-8x7b", "internvl2-26b"):
        cfg = get_config(arch)
        n = cfg.n_params()
        per_dev = n * 2 / 256       # bf16 over 256 chips
        opt = n * 12 / 256          # fp32 master+m+v
        row(f"table3/hbm/{arch}", 0,
            f"params={per_dev/2**30:.2f}GiB opt={opt/2**30:.2f}GiB "
            f"of {hw.hbm_bytes/2**30:.0f}GiB "
            f"({100*(per_dev+opt)/hw.hbm_bytes:.0f}%)")
